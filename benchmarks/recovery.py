"""Beyond-paper: the write-memory / log-length / recovery-time tradeoff,
end-to-end through the durability plane.

The paper's §4 couples write-memory allocation to transaction-log length:
more write memory means entries linger unflushed, the global min-LSN
advances slowly, and the un-truncated log tail grows. This benchmark
closes the loop the paper only argues: after a fixed zipf write workload
on a sharded store, crash it (clone the durable WAL + manifest) and
``recover`` -- measuring the retained log tail and the wall-clock replay
time. Larger write memory -> longer tail -> longer replay; the
``checkpoint_interval_bytes`` knob caps the tail regardless.

Rows: ``recovery/write_mem_<MB>MB`` (value = replay seconds) with
``log_tail_bytes`` / ``replay_time`` / ``replayed_records`` /
``replayed_keys`` in the derived fields, plus one
``recovery/checkpoint_interval`` row showing the knob bounding the tail.

Also here (physical storage plane): the fsync-policy matrix
``recovery/fsync_<policy>`` -- the same zipf workload on the *files*
medium under ``per_record`` / ``per_batch`` / ``group`` commit, reporting
``fsyncs_per_kop`` (the row value; WAL fsyncs only, page-store writes
excluded) and the commit-latency tail (``commit_p50_us`` /
``commit_p99_us`` from the WAL's group-commit histogram). Group commit
amortizes one fsync over many queued commits, so its ``fsyncs_per_kop``
must sit far (>=10x) below ``per_record``'s.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core.durability import recover
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import StoreConfig
from repro.core.shard import ShardedStore

from .common import BASE, KB, MB, fmt_row


def _drive(cfg: StoreConfig, n_ops: int, shards: int) -> ShardedStore:
    from .common import run_seed
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    store.create_tree("kv")
    rng = np.random.default_rng(7 + run_seed())
    batch = 256
    for _ in range(n_ops // batch):
        u = rng.random(batch)
        rank = np.floor(200_000 ** u).astype(np.int64)
        keys = (rank * 2654435761) % 200_000
        store.write_batch("kv", keys, keys + 1)
    return store


def _fsync_matrix(n_ops: int, shards: int) -> list:
    """files-medium commit-durability matrix: one row per fsync policy."""
    rows = []
    per_kop = {}
    for policy in ("per_record", "per_batch", "group"):
        root = tempfile.mkdtemp(prefix=f"bench-fsync-{policy}-")
        try:
            cfg = StoreConfig(**{
                **BASE, "max_log_bytes": 8 * MB,
                "storage_medium": "files", "storage_dir": root,
                "fsync_policy": policy,
                # a big interval + patient deadline so the group leader
                # batches many commits behind each fsync
                "group_commit_bytes": 1 * MB,
                "group_commit_max_wait_s": 0.25})
            store = _drive(cfg, n_ops, shards)
            store.wal.sync()
            wal = store.arena.wal
            fsyncs = wal.fsyncs            # WAL only: the commit cost
            kops = max(n_ops / 1000.0, 1e-9)
            per_kop[policy] = fsyncs / kops
            h = wal.commit_hist
            rows.append(fmt_row(
                f"recovery/fsync_{policy}", per_kop[policy],
                f"scheme={cfg.scheme};shards={shards};medium=files;"
                f"fsync_policy={policy};ops={n_ops};wal_fsyncs={fsyncs};"
                f"fsyncs_per_kop={per_kop[policy]:.6g};"
                f"commit_p50_us={h.quantile(0.5):.6g};"
                f"commit_p99_us={h.quantile(0.99):.6g};"
                f"wal_segments={wal.segment_count}"))
        finally:
            shutil.rmtree(root, ignore_errors=True)
    assert per_kop["group"] * 10 <= per_kop["per_record"], (
        f"group commit must amortize >=10x fewer fsyncs than per_record "
        f"(got {per_kop['group']:.3g} vs {per_kop['per_record']:.3g} "
        f"per kop)")
    return rows


def _crash_recover(cfg: StoreConfig, store: ShardedStore) -> dict:
    wal, manifest = store.wal.clone(), store.manifest.clone()
    t0 = time.perf_counter()
    recovered = recover(cfg, wal, manifest)
    replay_time = time.perf_counter() - t0
    info = recovered.recovery_info
    # recovered state must agree with the crashed store (cheap guardrail;
    # the differential suite proves bit-identity)
    assert recovered.log_pos == store.log_pos
    assert recovered.write_memory_used() == store.write_memory_used()
    return {"replay_time": replay_time, **info}


def run(full: bool = False, smoke: bool = False):
    n_ops = 6_000 if smoke else 60_000
    shards = 2
    mem_points = ([1, 8] if smoke else [1, 2, 4, 16]) if not full \
        else [1, 2, 4, 16, 32]
    rows = []
    for mem_mb in mem_points:
        # max_log_bytes stays finite: past the growth region the log cap
        # (log-triggered min-LSN flushes) bounds the tail -- the paper's
        # own recovery-time bound
        cfg = StoreConfig(**{**BASE,
                             "write_memory_bytes": mem_mb * MB,
                             "max_log_bytes": 8 * MB})
        store = _drive(cfg, n_ops, shards)
        r = _crash_recover(cfg, store)
        rows.append(fmt_row(
            f"recovery/write_mem_{mem_mb}MB", r["replay_time"],
            f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
            f"log_tail_bytes={r['tail_bytes']};"
            f"replay_bytes={r['replayed_bytes']};"
            f"replay_time={r['replay_time']:.6g};"
            f"replayed_records={r['replayed_records']};"
            f"replayed_keys={r['replayed_keys']}"))
    # the checkpoint-interval knob bounds the tail at the largest memory
    mem_mb = mem_points[-1]
    cfg = StoreConfig(**{**BASE,
                         "write_memory_bytes": mem_mb * MB,
                         "max_log_bytes": 8 * MB,
                         "checkpoint_interval_bytes": 256 * KB})
    store = _drive(cfg, n_ops, shards)
    r = _crash_recover(cfg, store)
    rows.append(fmt_row(
        "recovery/checkpoint_interval", r["replay_time"],
        f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
        f"ckpt_interval_kb=256;log_tail_bytes={r['tail_bytes']};"
        f"replay_bytes={r['replayed_bytes']};"
        f"replay_time={r['replay_time']:.6g};"
        f"replayed_records={r['replayed_records']};"
        f"replayed_keys={r['replayed_keys']}"))
    rows.extend(_fsync_matrix(n_ops, shards))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
