"""Beyond-paper: the write-memory / log-length / recovery-time tradeoff,
end-to-end through the durability plane.

The paper's §4 couples write-memory allocation to transaction-log length:
more write memory means entries linger unflushed, the global min-LSN
advances slowly, and the un-truncated log tail grows. This benchmark
closes the loop the paper only argues: after a fixed zipf write workload
on a sharded store, crash it (clone the durable WAL + manifest) and
``recover`` -- measuring the retained log tail and the wall-clock replay
time. Larger write memory -> longer tail -> longer replay; the
``checkpoint_interval_bytes`` knob caps the tail regardless.

Rows: ``recovery/write_mem_<MB>MB`` (value = replay seconds) with
``log_tail_bytes`` / ``replay_time`` / ``replayed_records`` /
``replayed_keys`` in the derived fields, plus one
``recovery/checkpoint_interval`` row showing the knob bounding the tail.

Also here (physical storage plane): the fsync-policy matrix
``recovery/fsync_<policy>`` -- the same zipf workload on the *files*
medium under ``per_record`` / ``per_batch`` / ``group`` commit, reporting
``fsyncs_per_kop`` (the row value; WAL fsyncs only, page-store writes
excluded) and the commit-latency tail (``commit_p50_us`` /
``commit_p99_us`` from the WAL's group-commit histogram). Group commit
amortizes one fsync over many queued commits, so its ``fsyncs_per_kop``
must sit far (>=10x) below ``per_record``'s. The ``recovery/async_fsync``
row runs the same group policy (same knobs as ``fsync_group_tight``)
with ``wal_async_fsync=True`` -- the leader hands the fsync to the
durability worker, so at (near-)equal ``fsyncs_per_kop`` the foreground
stops paying fsync time on the commit path: ``fsync_wait_us``
(foreground us blocked on WAL durability -- whole inline fsyncs when
blocking, only residual barriers when async) collapses. Ack latency
(``commit_p99_us``) holds parity on a single-core host, where the
handoff adds scheduler latency the freed foreground can't spend
elsewhere; with >=2 cores the worker's wait timer additionally closes
call-free aging groups sooner than the next commit call would.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core.durability import recover
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import StoreConfig
from repro.core.shard import ShardedStore

from .common import BASE, KB, MB, fmt_row


def _drive(cfg: StoreConfig, n_ops: int, shards: int) -> ShardedStore:
    from .common import run_seed
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    store.create_tree("kv")
    rng = np.random.default_rng(7 + run_seed())
    batch = 256
    for _ in range(n_ops // batch):
        u = rng.random(batch)
        rank = np.floor(200_000 ** u).astype(np.int64)
        keys = (rank * 2654435761) % 200_000
        store.write_batch("kv", keys, keys + 1)
    return store


def _fsync_matrix(n_ops: int, shards: int) -> list:
    """files-medium commit-durability matrix: one row per fsync policy,
    plus the async-group-commit arm (``recovery/async_fsync``): the same
    group policy with the fsync handed to the durability worker. At
    (near-)equal ``fsyncs_per_kop`` the async arm's ``fsync_wait_us``
    must come in far below ``fsync_group_tight``'s -- the foreground no
    longer eats whole fsyncs, only the residual sync/seal barriers --
    while ``commit_p99_us`` holds parity (single-core host; see the
    module docstring)."""
    rows = []
    per_kop = {}
    p99 = {}
    wait_us = {}
    # (label, policy, async, group_bytes, group_max_wait_s). The classic
    # three keep the big-interval/patient-deadline config so the leader
    # batches many commits behind each fsync; the async pair runs a
    # moderate interval with a deadline short enough that the age rule
    # (not just the byte rule) closes groups -- the regime where the
    # durability worker's own timer matters. group_tight and group_async
    # share EXACT knobs, so their p99 delta isolates the handoff.
    arms = [("per_record", "per_record", False, 1 * MB, 0.25),
            ("per_batch", "per_batch", False, 1 * MB, 0.25),
            ("group", "group", False, 1 * MB, 0.25),
            ("group_tight", "group", False, 64 * KB, 0.002),
            ("group_async", "group", True, 64 * KB, 0.002)]
    for label, policy, async_fsync, gbytes, gwait in arms:
        root = tempfile.mkdtemp(prefix=f"bench-fsync-{label}-")
        try:
            cfg = StoreConfig(**{
                **BASE, "max_log_bytes": 8 * MB,
                "storage_medium": "files", "storage_dir": root,
                "fsync_policy": policy,
                "wal_async_fsync": async_fsync,
                "group_commit_bytes": gbytes,
                "group_commit_max_wait_s": gwait})
            store = _drive(cfg, n_ops, shards)
            store.wal.sync()
            wal = store.arena.wal
            fsyncs = wal.fsyncs            # WAL only: the commit cost
            kops = max(n_ops / 1000.0, 1e-9)
            per_kop[label] = fsyncs / kops
            h = wal.commit_hist
            p99[label] = h.quantile(0.99)
            fsync_wait = wait_us[label] = store.disk.stats.fsync_wait_us
            rows.append(fmt_row(
                f"recovery/{'async_fsync' if async_fsync else 'fsync_' + label}",
                per_kop[label],
                f"scheme={cfg.scheme};shards={shards};medium=files;"
                f"fsync_policy={policy};async={async_fsync};ops={n_ops};"
                f"wal_fsyncs={fsyncs};"
                f"fsyncs_per_kop={per_kop[label]:.6g};"
                f"commit_p50_us={h.quantile(0.5):.6g};"
                f"commit_p99_us={p99[label]:.6g};"
                f"fsync_wait_us={fsync_wait:.6g};"
                f"wal_segments={wal.segment_count}"))
            store.wal.close()
        finally:
            shutil.rmtree(root, ignore_errors=True)
    assert per_kop["group"] * 10 <= per_kop["per_record"], (
        f"group commit must amortize >=10x fewer fsyncs than per_record "
        f"(got {per_kop['group']:.3g} vs {per_kop['per_record']:.3g} "
        f"per kop)")
    assert wait_us["group_async"] * 2 <= wait_us["group_tight"], (
        f"async handoff must take most foreground durability blocking "
        f"off the commit path (got fsync_wait_us "
        f"{wait_us['group_async']:.3g} async vs "
        f"{wait_us['group_tight']:.3g} blocking at the same knobs)")
    return rows


def _crash_recover(cfg: StoreConfig, store: ShardedStore) -> dict:
    wal, manifest = store.wal.clone(), store.manifest.clone()
    t0 = time.perf_counter()
    recovered = recover(cfg, wal, manifest)
    replay_time = time.perf_counter() - t0
    info = recovered.recovery_info
    # recovered state must agree with the crashed store (cheap guardrail;
    # the differential suite proves bit-identity)
    assert recovered.log_pos == store.log_pos
    assert recovered.write_memory_used() == store.write_memory_used()
    return {"replay_time": replay_time, **info}


def run(full: bool = False, smoke: bool = False):
    n_ops = 6_000 if smoke else 60_000
    shards = 2
    mem_points = ([1, 8] if smoke else [1, 2, 4, 16]) if not full \
        else [1, 2, 4, 16, 32]
    rows = []
    for mem_mb in mem_points:
        # max_log_bytes stays finite: past the growth region the log cap
        # (log-triggered min-LSN flushes) bounds the tail -- the paper's
        # own recovery-time bound
        cfg = StoreConfig(**{**BASE,
                             "write_memory_bytes": mem_mb * MB,
                             "max_log_bytes": 8 * MB})
        store = _drive(cfg, n_ops, shards)
        r = _crash_recover(cfg, store)
        rows.append(fmt_row(
            f"recovery/write_mem_{mem_mb}MB", r["replay_time"],
            f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
            f"log_tail_bytes={r['tail_bytes']};"
            f"replay_bytes={r['replayed_bytes']};"
            f"replay_time={r['replay_time']:.6g};"
            f"replayed_records={r['replayed_records']};"
            f"replayed_keys={r['replayed_keys']}"))
    # the checkpoint-interval knob bounds the tail at the largest memory
    mem_mb = mem_points[-1]
    cfg = StoreConfig(**{**BASE,
                         "write_memory_bytes": mem_mb * MB,
                         "max_log_bytes": 8 * MB,
                         "checkpoint_interval_bytes": 256 * KB})
    store = _drive(cfg, n_ops, shards)
    r = _crash_recover(cfg, store)
    rows.append(fmt_row(
        "recovery/checkpoint_interval", r["replay_time"],
        f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
        f"ckpt_interval_kb=256;log_tail_bytes={r['tail_bytes']};"
        f"replay_bytes={r['replayed_bytes']};"
        f"replay_time={r['replay_time']:.6g};"
        f"replayed_records={r['replayed_records']};"
        f"replayed_keys={r['replayed_keys']}"))
    rows.extend(_fsync_matrix(n_ops, shards))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
