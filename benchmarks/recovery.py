"""Beyond-paper: the write-memory / log-length / recovery-time tradeoff,
end-to-end through the durability plane.

The paper's §4 couples write-memory allocation to transaction-log length:
more write memory means entries linger unflushed, the global min-LSN
advances slowly, and the un-truncated log tail grows. This benchmark
closes the loop the paper only argues: after a fixed zipf write workload
on a sharded store, crash it (clone the durable WAL + manifest) and
``recover`` -- measuring the retained log tail and the wall-clock replay
time. Larger write memory -> longer tail -> longer replay; the
``checkpoint_interval_bytes`` knob caps the tail regardless.

Rows: ``recovery/write_mem_<MB>MB`` (value = replay seconds) with
``log_tail_bytes`` / ``replay_time`` / ``replayed_records`` /
``replayed_keys`` in the derived fields, plus one
``recovery/checkpoint_interval`` row showing the knob bounding the tail.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.durability import recover
from repro.core.lsm.sstable import reset_sst_ids
from repro.core.lsm.storage import StoreConfig
from repro.core.shard import ShardedStore

from .common import BASE, KB, MB, fmt_row


def _drive(cfg: StoreConfig, n_ops: int, shards: int) -> ShardedStore:
    reset_sst_ids()
    store = ShardedStore(cfg, shards=shards)
    store.create_tree("kv")
    rng = np.random.default_rng(7)
    batch = 256
    for _ in range(n_ops // batch):
        u = rng.random(batch)
        rank = np.floor(200_000 ** u).astype(np.int64)
        keys = (rank * 2654435761) % 200_000
        store.write_batch("kv", keys, keys + 1)
    return store


def _crash_recover(cfg: StoreConfig, store: ShardedStore) -> dict:
    wal, manifest = store.wal.clone(), store.manifest.clone()
    t0 = time.perf_counter()
    recovered = recover(cfg, wal, manifest)
    replay_time = time.perf_counter() - t0
    info = recovered.recovery_info
    # recovered state must agree with the crashed store (cheap guardrail;
    # the differential suite proves bit-identity)
    assert recovered.log_pos == store.log_pos
    assert recovered.write_memory_used() == store.write_memory_used()
    return {"replay_time": replay_time, **info}


def run(full: bool = False, smoke: bool = False):
    n_ops = 6_000 if smoke else 60_000
    shards = 2
    mem_points = ([1, 8] if smoke else [1, 2, 4, 16]) if not full \
        else [1, 2, 4, 16, 32]
    rows = []
    for mem_mb in mem_points:
        # max_log_bytes stays finite: past the growth region the log cap
        # (log-triggered min-LSN flushes) bounds the tail -- the paper's
        # own recovery-time bound
        cfg = StoreConfig(**{**BASE,
                             "write_memory_bytes": mem_mb * MB,
                             "max_log_bytes": 8 * MB})
        store = _drive(cfg, n_ops, shards)
        r = _crash_recover(cfg, store)
        rows.append(fmt_row(
            f"recovery/write_mem_{mem_mb}MB", r["replay_time"],
            f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
            f"log_tail_bytes={r['tail_bytes']};"
            f"replay_bytes={r['replayed_bytes']};"
            f"replay_time={r['replay_time']:.6g};"
            f"replayed_records={r['replayed_records']};"
            f"replayed_keys={r['replayed_keys']}"))
    # the checkpoint-interval knob bounds the tail at the largest memory
    mem_mb = mem_points[-1]
    cfg = StoreConfig(**{**BASE,
                         "write_memory_bytes": mem_mb * MB,
                         "max_log_bytes": 8 * MB,
                         "checkpoint_interval_bytes": 256 * KB})
    store = _drive(cfg, n_ops, shards)
    r = _crash_recover(cfg, store)
    rows.append(fmt_row(
        "recovery/checkpoint_interval", r["replay_time"],
        f"scheme={cfg.scheme};shards={shards};write_mem_mb={mem_mb};"
        f"ckpt_interval_kb=256;log_tail_bytes={r['tail_bytes']};"
        f"replay_bytes={r['replayed_bytes']};"
        f"replay_time={r['replay_time']:.6g};"
        f"replayed_records={r['replayed_records']};"
        f"replayed_keys={r['replayed_keys']}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
