"""Fig. 16: tuner accuracy on TPC-C — tuned allocation vs exhaustive
search vs fixed baselines (small write memory / 50-50 split).

Paper claims: tuned weighted I/O cost ~ exhaustive optimum; both fixed
baselines are worse. Weights: omega=2 (SSD writes), gamma=1.
"""
from __future__ import annotations

from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

from .common import MB, fmt_row, make_store, measure
from .tpcc import TPCC

OMEGA, GAMMA = 2.0, 1.0


def weighted_cost(m):
    return OMEGA * m["write_pages_per_op"] + GAMMA * m["read_pages_per_op"]


def fixed_run(write_mem_mb, total_mb, n_txns):
    store = make_store(total_memory_bytes=total_mb * MB,
                       write_memory_bytes=int(write_mem_mb * MB),
                       max_log_bytes=8 * MB, flush_policy="opt")
    drv = TPCC(store)
    drv.run(n_txns // 4)                      # warm-up (excluded)
    m = measure(store, lambda: drv.run(n_txns))
    m["wcost"] = weighted_cost(m)
    return m


def tuned_run(total_mb, n_txns):
    store = make_store(total_memory_bytes=total_mb * MB,
                       write_memory_bytes=2 * MB, max_log_bytes=8 * MB,
                       flush_policy="opt")
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        omega=OMEGA, gamma=GAMMA, min_step_bytes=256 * 1024,
        ops_cycle=2_000, min_write_mem=1 * MB))
    drv = TPCC(store)
    drv.run(n_txns // 2, on_txn=lambda: ctrl.maybe_tune())  # tuning warm-up
    m = measure(store, lambda: drv.run(n_txns,
                                       on_txn=lambda: ctrl.maybe_tune()))
    m["wcost"] = weighted_cost(m)
    m["x_mb"] = store.write_memory_bytes / MB
    return m


def run(full: bool = False):
    rows = []
    total = 96
    n = 10_000 if full else 3_000
    fracs = [1 / 32, 1 / 16, 1 / 8, 1 / 4, 3 / 8, 1 / 2] if full \
        else [1 / 16, 1 / 4, 1 / 2]
    sweep = {}
    for f in fracs:
        m = fixed_run(total * f, total, n)
        sweep[f] = m["wcost"]
        rows.append(fmt_row(f"fig16/fixed_{f:.3f}", m["wcost"],
                            f"thr={m['throughput']:.0f}"))
    opt = min(sweep.values())
    m = tuned_run(total, n)
    rows.append(fmt_row("fig16/tuned", m["wcost"],
                        f"x={m['x_mb']:.1f}MB;opt={opt:.3f};"
                        f"ratio={m['wcost']/max(opt,1e-9):.2f}"))
    m50 = sweep.get(1 / 2) or fixed_run(total / 2, total, n)["wcost"]
    msm = sweep.get(1 / 32) or fixed_run(total / 32, total, n)["wcost"]
    rows.append(fmt_row("fig16/baseline_50pct", m50, ""))
    rows.append(fmt_row("fig16/baseline_small", msm, ""))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
