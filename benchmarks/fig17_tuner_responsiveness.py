"""Fig. 17/18: tuner responsiveness — TPC-C shifts from the default mix to
a read-mostly mix halfway; the tuner re-allocates toward the buffer cache.
Fig. 18 ablation: larger max step sizes respond faster but oscillate.
"""
from __future__ import annotations

from repro.core.tuner.tuner import AdaptiveMemoryController, TunerConfig

from .common import MB, fmt_row, make_store, measure
from .tpcc import READ_MOSTLY, TPCC


def one(max_shrink, n_txns=8_000, total_mb=64):
    store = make_store(total_memory_bytes=total_mb * MB,
                       write_memory_bytes=16 * MB, max_log_bytes=8 * MB,
                       flush_policy="opt")
    # min_rel_gain rescaled for the 64x-scaled-down setup (absolute costs
    # per byte of step are ~64x smaller than the paper's GB-scale steps)
    ctrl = AdaptiveMemoryController(store, TunerConfig(
        omega=2.0, gamma=1.0, min_step_bytes=256 * 1024, ops_cycle=1_000,
        min_write_mem=1 * MB, max_shrink_frac=max_shrink,
        min_rel_gain=0.0002))
    drv = TPCC(store)
    xs = []

    def on_txn():
        if ctrl.maybe_tune():
            xs.append(store.write_memory_bytes / MB)

    drv.run(n_txns // 2, on_txn=on_txn)
    x_mid = store.write_memory_bytes / MB
    m = measure(store, lambda: drv.run(n_txns // 2, mix=READ_MOSTLY,
                                       on_txn=on_txn))
    return {"x_mid": x_mid, "x_end": store.write_memory_bytes / MB,
            "trajectory": xs, "wcost": 2 * m["write_pages_per_op"]
            + m["read_pages_per_op"]}


def run(full: bool = False):
    rows = []
    shrinks = [0.1, 0.5, 1.0] if full else [0.1, 1.0]
    n = 12_000 if full else 5_000
    for s in shrinks:
        r = one(s, n_txns=n)
        rows.append(fmt_row(
            f"fig17_18/max_step{int(s*100)}pct", r["x_end"],
            f"x_mid={r['x_mid']:.1f}MB;steps={len(r['trajectory'])};"
            f"wcost={r['wcost']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
