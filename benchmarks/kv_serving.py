"""Beyond-paper: the memory-walls policies on serving-shaped workloads.

Two scenarios:

* **HBM split** -- fixed 50/50 HBM split between the KV page pool and the
  prefix cache vs the adaptive HBM tuner, under a prefix-reuse-heavy and
  an append-heavy phase. Cost = offload pages + recompute pages per op.
* **LSM hot-key skew** -- a multi-tenant LSM store (one hot tree taking
  most of a zipf-skewed write stream, three cold trees) driven through
  the batched write path, so the maintenance scheduler has to arbitrate
  flushes/merges *across* trees sharing one write memory. Compares the
  §4.2 flush policies and a bounded per-tick merge budget.
* **Read hot path** -- fixed-size Get batches over a growing last level,
  staged (device pool off) vs fused (device-resident tier lookups):
  staged pays one Bloom+search backend call per touched SSTable, so host
  lookup latency grows ~linearly in SSTable count; fused collapses the
  tier into one probe+search pass, growing sub-linearly.
* **Paced maintenance** -- the same write-heavy stream under stop-the-world
  ticks (every submit drains all merge debt in-line) vs the
  ``MaintenancePacer`` (bounded merge slices released against the observed
  write rate). Throughput is ~equal -- the same debt gets paid either
  way -- but the paced tail (p999 request latency, max maintenance stall)
  collapses because no single submit carries a whole merge cascade.
* **Overlapped maintenance** -- the paced stream plus a read sidecar,
  swept over ``maintenance_workers`` (0/1/2/4). The prepare/apply split
  moves merge sorts and bloom builds onto worker threads while every
  side effect stays at its deterministic segment boundary: all arms
  produce byte-identical data (fingerprint-asserted) and identical
  logical columns; ``bg_segments`` / ``bg_overlap_us`` count the compute
  actually taken off the foreground path.
"""
from __future__ import annotations

import gc
import time

import numpy as np

from repro.core.lsm.sstable import partition_run
from repro.core.service import AdaptiveGovernor, Delete, Get, Put, Scan
from repro.core.shard import ShardRouter
from repro.core.tuner.tuner import TunerConfig
from repro.runtime.hbm_arbiter import HBMArbiter, HBMArbiterConfig
from repro.runtime.hbm_tuner import HBMTuner, HBMTunerConfig
from repro.runtime.kvcache import KVPoolConfig, PagedKVPool

from .common import (BASE, KB, MB, Workload, bulk_load, fmt_row,
                     make_service, make_sharded_service, measure)


def drive(pool, tuner, n_ops, reuse_frac, rng, working_set=1600,
          req_pages=96, n_streams=24):
    """Requests have finite lifetimes (req_pages) and then free their
    pages — so a bigger pool means fewer offloads (diminishing returns),
    mirroring the LSM write-memory/write-cost relationship."""
    lens = {}
    for i in range(n_ops):
        if rng.random() < reuse_frac:
            pool.lookup_prefix(int(rng.integers(0, working_set)))
        else:
            s = f"s{rng.integers(0, n_streams)}"
            pool.append_tokens(s, pool.cfg.page_tokens)
            lens[s] = lens.get(s, 0) + 1
            if lens[s] >= req_pages:
                pool.finish_stream(s)
                lens[s] = 0
        if tuner is not None:
            tuner.maybe_tune()


def cost_per_op(stats0, stats1, ops):
    off = stats1["offload_pages"] - stats0["offload_pages"]
    rec = (stats1["prefix_misses"] - stats0["prefix_misses"])
    return (off + rec) / max(ops, 1)


def one(adaptive: bool, n_ops=40_000):
    pool = PagedKVPool(KVPoolConfig(page_tokens=16, total_pages=2048,
                                    pool_pages=1024, sim_pages=256,
                                    policy="opt"))
    tuner = HBMTuner(pool, HBMTunerConfig(ops_cycle=1024)) if adaptive \
        else None
    rng = np.random.default_rng(0)
    costs = []
    for phase, reuse in enumerate([0.85, 0.25]):     # reuse-heavy -> append-heavy
        s0 = dict(pool.stats)
        drive(pool, tuner, n_ops // 2, reuse, rng)
        costs.append(cost_per_op(s0, pool.stats, n_ops // 2))
    return {"costs": costs, "pool_pages": pool.cfg.pool_pages,
            "total_cost": sum(costs)}


def lsm_hot_key(policy: str, n_ops: int, *, merge_budget=None,
                n_trees=4, n_records=60_000, write_mem_bytes=1 * MB):
    """Skewed multi-tenant serving through the StorageService front door:
    tree 0 takes ~85% of a zipf write stream; the scheduler arbitrates
    cross-tree flushes/merges; admission control turns L0 pile-ups under a
    bounded merge budget into visible write_stalls (drained + retried)."""
    svc = make_service(write_memory_bytes=write_mem_bytes,
                       max_log_bytes=8 * MB,
                       flush_policy=policy, merge_budget=merge_budget)
    names = [f"tenant{i}" for i in range(n_trees)]
    for name in names:
        svc.create_tree(name)
        bulk_load(svc.store, name, n_records)
    probs = [0.85] + [0.15 / (n_trees - 1)] * (n_trees - 1)
    w = Workload(svc, names, n_records, tree_probs=probs, seed=3)
    m = measure(svc, lambda: w.run(n_ops, write_frac=0.7))
    m["carried_debt"] = svc.store.scheduler.carried_debt
    m["ticks"] = svc.store.scheduler.ticks
    return m


def service_mixed(n_ops: int, *, n_trees=3, n_records=20_000):
    """Mixed-op request plans: every submit is one shuffled batch of
    Put/Get/Delete/Scan requests across tenant trees, planned into
    vectorized per-(tree, kind) steps by the service. Per-tenant sessions
    meter the write admission window."""
    svc = make_service(write_memory_bytes=1 * MB, max_log_bytes=8 * MB,
                       flush_policy="opt")
    names = [f"tenant{i}" for i in range(n_trees)]
    for name in names:
        svc.create_tree(name)
        bulk_load(svc.store, name, n_records)
    sessions = [svc.session(n, max_outstanding_keys=4096) for n in names]
    rng = np.random.default_rng(11)
    done = 0
    while done < n_ops:
        reqs = []
        batch_ops = 0
        for _ in range(int(rng.integers(2, 6))):
            t = names[int(rng.integers(0, n_trees))]
            r = rng.random()
            ks = rng.integers(0, n_records, size=int(rng.integers(32, 256)))
            if r < 0.45:
                reqs.append(Put(t, ks, ks))
                batch_ops += len(ks)
            elif r < 0.60:
                reqs.append(Delete(t, ks[:32]))
                batch_ops += 32
            elif r < 0.90:
                reqs.append(Get(t, ks))
                batch_ops += len(ks)
            else:
                reqs.append(Scan(t, int(ks[0]), 100))
                batch_ops += 1
        rng.shuffle(reqs)
        sess = sessions[int(rng.integers(0, n_trees))]
        sess.submit_all(reqs)
        done += batch_ops
    svc.store.sync_mem_stats()
    st = svc.stats
    return {"submits": svc.submits, "ops": st.ops, "stalls": st.write_stalls,
            "throughput": svc.store.throughput(),
            "deferred": sum(s.stats.deferred_events
                            for s in sessions)}


def read_hot_path(n_batches: int, *, sst_count=16, batch=256, fused=True):
    """Read-heavy hot path: fixed-size Get batches over a last level of
    ``sst_count`` SSTables. ``fused=False`` runs the staged path (device
    pool disabled, one Bloom probe + one ranged search per touched
    SSTable); ``fused=True`` gives the pool enough budget to hold the
    whole level, so after one cold acquire every batch resolves through
    ``lookup_fused``. Host wall-time percentiles per lookup make the
    scaling visible: staged grows ~linearly in ``sst_count`` at fixed
    batch size, fused sub-linearly."""
    per_sst = BASE["sstable_bytes"] // BASE["entry_bytes"]
    n_records = sst_count * per_sst
    svc = make_service(device_pool_bytes=(64 * MB if fused else 0))
    svc.create_tree("kv")
    bulk_load(svc.store, "kv", n_records)
    rng = np.random.default_rng(5)
    # warm-up: jit shape buckets + pool residency (the first acquire is a
    # cold miss that admits the tier; fused serves from the second batch)
    for _ in range(2):
        svc.submit_strict([Get("kv", rng.integers(0, n_records, batch))])
    lat = []

    def drive():
        for _ in range(n_batches):
            ks = rng.integers(0, n_records, size=batch)
            t0 = time.perf_counter()
            svc.submit_strict([Get("kv", ks)])
            lat.append((time.perf_counter() - t0) / batch * 1e6)

    m = measure(svc, drive)
    m["lookup_p50_us"] = float(np.percentile(lat, 50))
    m["lookup_p99_us"] = float(np.percentile(lat, 99))
    m["sst_count"] = len(svc.store.trees["kv"].levels.levels[-1])
    return m


def _install_multilevel(store, tree: str, n_records: int) -> None:
    """Install a 4-level tree with a fixed 1/3/12/48-sixty-fourths key
    split (multiplicative hash, exact: the odd constant is invertible mod
    64, so key*C mod 64 is a bijection on residues). Levels overlap in key
    range -- every lookup tier covers the whole keyspace -- but each KEY
    lives in exactly one level, so per-tier probing cannot early-exit."""
    keys = np.arange(n_records, dtype=np.int64)
    h = (keys * 2654435761) % 64
    t = store.trees[tree]
    sels = [h < 1, (h >= 1) & (h < 4), (h >= 4) & (h < 16), h >= 16]
    for li, sel in enumerate(sels):
        lk = keys[sel]
        t.levels.levels[li] = partition_run(
            lk, lk, 0, 0, t.entry_bytes, store.cfg.page_bytes,
            store.cfg.sstable_bytes)


def cross_tier_read(n_batches: int, *, batch=256, fused_scope=None,
                    n_records=131_072, per_sst=2048, backend=None):
    """One launch per lookup batch across ALL tiers: fixed Get batches
    over a static 4-level tree (keys split across levels, so every batch
    must consult every tier). ``fused_scope=None`` runs staged (device
    pool off, one backend call per touched SSTable); ``"tier"`` runs the
    PR-6 path (one launch per TIER per batch); ``"store"`` stacks every
    tier into one ragged layout and probes the whole store in a single
    launch -- ``launches_per_batch`` is the visible O(tiers) -> 1 drop.
    ``backend`` pins the store's execution backend (the launch-bound
    regime lives on the device backend, where a launch is a real kernel
    dispatch; on the numpy reference a launch is just a function call)."""
    kw = dict(size_ratio=4, dynamic_levels=False, static_num_levels=4,
              sstable_bytes=per_sst * BASE["entry_bytes"])
    if backend is not None:
        kw["backend"] = backend
    if fused_scope is None:
        kw["device_pool_bytes"] = 0
    else:
        kw.update(device_pool_bytes=64 * MB, fused_scope=fused_scope)
    svc = make_service(**kw)
    svc.create_tree("kv")
    _install_multilevel(svc.store, "kv", n_records)
    rng = np.random.default_rng(17)
    # warm-up: jit shape buckets + pool residency (first acquire cold-admits)
    for _ in range(2):
        svc.submit_strict([Get("kv", rng.integers(0, n_records, batch))])
    lat = []

    def drive():
        for _ in range(n_batches):
            ks = rng.integers(0, n_records, size=batch)
            t0 = time.perf_counter()
            svc.submit_strict([Get("kv", ks)])
            lat.append((time.perf_counter() - t0) / batch * 1e6)

    m = measure(svc, drive)
    m["lookup_p50_us"] = float(np.percentile(lat, 50))
    m["lookup_p99_us"] = float(np.percentile(lat, 99))
    m["launches_per_batch"] = m["fused_launches"] / n_batches
    m["sst_total"] = sum(len(lv) for lv
                         in svc.store.trees["kv"].levels.levels)
    return m


def arbiter_flip(n_ops: int, *, n_records=32_768, batch=256):
    """Read-heavy -> serving-heavy workload flip under the unified HBM
    arbiter: one total budget leased across the lookup-side device pool
    and the serving-side KV pool/prefix cache. Phase A (pure Gets, device
    lease starved) migrates bytes device-ward; phase B (KV append churn
    offloading pages) migrates them back toward the KV pool. The lease
    sum is asserted byte-exact after every decision."""
    kvp = PagedKVPool(KVPoolConfig(page_tokens=16, total_pages=2048,
                                   pool_pages=1024, sim_pages=256))
    arb = HBMArbiter(kvp, HBMArbiterConfig(total_bytes=48 * MB,
                                           kv_page_bytes=16 * KB,
                                           ops_cycle=1024),
                     leases={"device": 2 * MB, "kv": 23 * MB,
                             "prefix": 23 * MB})
    svc = make_service(governor=arb, device_pool_bytes=2 * MB,
                       size_ratio=4, dynamic_levels=False,
                       static_num_levels=4,
                       sstable_bytes=2048 * BASE["entry_bytes"])
    svc.create_tree("kv")
    _install_multilevel(svc.store, "kv", n_records)
    rng = np.random.default_rng(23)
    for _ in range(max(1, n_ops // batch)):        # phase A: read-heavy
        svc.submit_strict([Get("kv", rng.integers(0, n_records, batch))])
        assert arb.total_leased() == arb.cfg.total_bytes
    dev_read = arb.leases["device"]
    for i in range(n_ops):                         # phase B: serving-heavy
        kvp.append_tokens(f"s{i % 16}", 16)
        if i % 64 == 0:
            svc.submit_strict([Get("kv", rng.integers(0, n_records, 32))])
            assert arb.total_leased() == arb.cfg.total_bytes
    return {"shift_bytes": arb.shift_bytes_total,
            "dev_read": dev_read, "dev_serve": arb.leases["device"],
            "kv_serve": arb.leases["kv"],
            "leases_sum": arb.total_leased(),
            "decisions": sum(1 for r in arb.records if r["shift_bytes"])}


def paced_maintenance(n_ops: int, *, paced: bool, n_trees=2,
                      n_records=120_000, write_mem_bytes=256 * KB,
                      batch=128, windows=16, interval_bytes=16 * KB,
                      segment_budget=1):
    """Sustained write stream, stop-the-world vs paced maintenance.

    ``paced=False`` runs the classic schedule: one full tick per submit,
    draining every runnable merge in-line, so a submit that lands on a
    flush pays the flush AND the merge work it unlocked (the p999
    spike). ``paced=True`` routes the same submits through the service's
    ``MaintenancePacer``: mandatory mem/log segments every pass, merge
    debt paid in ``segment_budget``-step slices released per
    ``interval_bytes`` of ingested payload and deferred past passes that
    flushed -- the worst pass pays max(flush, slice) instead of their
    sum. Wall-clock request-latency and maintenance-stall tails come
    from the service histograms via ``measure``
    (p50/p99/p999/max_stall columns).

    The driver rotates write batches across ``windows`` disjoint key
    ranges, so L0 runs form many non-overlapping groups (zipf streams
    coalesce into one group and merge in single units) and the L0 byte
    budget releases real multi-unit merge work for the schedulers to
    place. The stream is pure writes: deferral trades transient read-amp
    (L0 runs linger a few submits longer) for the write-stall tail, so
    the read tail is the read_hot_path scenario's job, not this one's.
    GC is parked during the measured window -- the tail columns are
    wall-clock and a collection pause would charge an arbitrary
    submit."""
    kw = dict(write_memory_bytes=write_mem_bytes, max_log_bytes=8 * MB,
              flush_policy="opt", l0_target_groups=64, l0_max_groups=64)
    if paced:
        kw.update(pacer_interval_bytes=interval_bytes,
                  pacer_segment_budget=segment_budget)
    svc = make_service(**kw)
    names = [f"kv{i}" for i in range(n_trees)]
    for name in names:
        svc.create_tree(name)
        bulk_load(svc.store, name, n_records)
    rng = np.random.default_rng(13)
    span = n_records // windows

    def drive():
        gc.disable()
        try:
            for i in range(n_ops // batch):
                w = (i * 7919) % windows
                ks = rng.integers(w * span, (w + 1) * span, size=batch)
                svc.submit_strict([Put(names[i % n_trees], ks, ks + 1)])
        finally:
            gc.enable()
            gc.collect()

    m = measure(svc, drive)
    sch = svc.store.scheduler
    m["slices"] = svc.pacer.slices if svc.pacer is not None else 0
    m["deferrals"] = svc.pacer.deferrals if svc.pacer is not None else 0
    m["segments"] = sch.segments
    m["ticks"] = sch.ticks
    m["carried_debt"] = sch.carried_debt
    return m


def _data_fingerprint(svc, names, n_records) -> int:
    """Cheap content fingerprint: CRC over a fixed probe sample of every
    tree. The overlapped_maintenance arms must agree byte-for-byte -- the
    worker pool's determinism contract, checked at benchmark scale."""
    import zlib
    rng = np.random.default_rng(99)
    out = 0
    for name in names:
        ks = rng.integers(0, n_records, size=4096)
        found, vals = svc.store.read_batch(name, ks)
        out = zlib.crc32(vals[found].tobytes(),
                         zlib.crc32(found.tobytes(), out))
    return out


def overlapped_maintenance(n_ops: int, *, workers: int, n_trees=2,
                           n_records=120_000, write_mem_bytes=256 * KB,
                           batch=128, windows=16, interval_bytes=16 * KB,
                           segment_budget=1, flush_threshold=0.5,
                           read_every=4):
    """The paced_maintenance stream plus a read sidecar, swept over
    ``maintenance_workers``. Workers run the compute-heavy prepares
    (``merge_runs`` sorts, bloom builds) off-thread against immutable
    snapshots while the foreground keeps every side effect at its
    deterministic segment boundary -- so the *logical* columns (ops, I/O
    per op, flush_slices, simulated throughput) are identical across
    arms (fingerprint-checked by the caller). The overlap counters
    (``bg_segments`` prepares consumed, ``bg_overlap_us`` compute moved
    off-thread) prove the machinery engages; the wall-clock tail
    (``max_stall_us``, p999) is where the overlap pays off, but ONLY on
    a multi-core host -- on a single core (this repo's CI) the worker
    threads time-slice against the foreground, so the arms track within
    run-to-run noise there and the counters are the signal to read.
    ``pacer_flush_threshold`` adds proactive partial flush slices,
    spreading flush work across passes instead of bursting at the hard
    threshold."""
    kw = dict(write_memory_bytes=write_mem_bytes, max_log_bytes=8 * MB,
              flush_policy="opt", l0_target_groups=64, l0_max_groups=64,
              pacer_interval_bytes=interval_bytes,
              pacer_segment_budget=segment_budget,
              pacer_flush_threshold=flush_threshold,
              maintenance_workers=workers)
    svc = make_service(**kw)
    names = [f"kv{i}" for i in range(n_trees)]
    for name in names:
        svc.create_tree(name)
        bulk_load(svc.store, name, n_records)
    rng = np.random.default_rng(13)
    span = n_records // windows

    def drive():
        gc.disable()
        try:
            for i in range(n_ops // batch):
                w = (i * 7919) % windows
                ks = rng.integers(w * span, (w + 1) * span, size=batch)
                svc.submit_strict([Put(names[i % n_trees], ks, ks + 1)])
                if i % read_every == read_every - 1:
                    # reads consume worker-prepared bloom filters of the
                    # freshly merged tables (and pay for building them
                    # inline on the workers=0 arm)
                    svc.submit_strict([Get(
                        names[i % n_trees],
                        rng.integers(0, n_records, batch))])
        finally:
            gc.enable()
            gc.collect()

    m = measure(svc, drive)
    pool = svc.store.arena.workers
    m["fingerprint"] = _data_fingerprint(svc, names, n_records)
    m["prepares"] = pool.submitted
    m["hits"] = pool.hits
    m["wasted"] = pool.wasted
    m["slices"] = svc.pacer.slices
    m["deferrals"] = svc.pacer.deferrals
    pool.close()
    return m


def sharded_hot_shard(n_ops: int, *, shards=4, n_records=40_000,
                      write_mem_bytes=1 * MB, hot_frac=0.85,
                      write_frac=0.7, batch=256):
    """Sharded hot-shard YCSB: a range-partitioned keyspace where
    ``hot_frac`` of the traffic lands in shard 0's range. Because every
    shard draws from ONE memory arena, the skew becomes a cross-shard
    memory wall: the global scheduler's write-rate-proportional (OPT)
    flush ranking keeps flushing the cold shards' trees, so the shared
    write memory migrates to the hot shard (``hot_mem_share`` well above
    1/shards) while the governor keeps tuning the global boundary."""
    router = ShardRouter.ranges(shards, n_records)
    governor = AdaptiveGovernor(TunerConfig(
        min_step_bytes=256 * 1024, ops_cycle=2_000, min_write_mem=1 * MB))
    svc = make_sharded_service(router=router, governor=governor,
                               write_memory_bytes=write_mem_bytes,
                               max_log_bytes=8 * MB, flush_policy="opt")
    svc.create_tree("kv")
    bulk_load(svc.store, "kv", n_records)
    rng = np.random.default_rng(7)
    hot_hi = n_records // shards          # shard 0's key range

    def drive():
        done = 0
        while done < n_ops:
            lo, hi = (0, hot_hi) if rng.random() < hot_frac \
                else (hot_hi, n_records)
            ks = rng.integers(lo, hi, size=batch)
            if rng.random() < write_frac:
                svc.submit_strict([Put("kv", ks, ks)])
            else:
                svc.submit_strict([Get("kv", ks)])
            done += batch

    m = measure(svc, drive)
    per = svc.store.shard_tree_stats()
    total_mem = max(1, sum(a["mem_bytes"] for a in per))
    flushed = [a["bytes_flushed_mem"] + a["bytes_flushed_log"] for a in per]
    m["shards"] = shards
    m["hot_mem_share"] = per[0]["mem_bytes"] / total_mem
    m["hot_flush_share"] = flushed[0] / max(1, sum(flushed))
    m["tuning_steps"] = len(governor.records)
    return m


def run(full: bool = False, smoke: bool = False):
    n = 2_000 if smoke else (80_000 if full else 24_000)
    rows = []
    fixed = one(False, n)
    adap = one(True, n)
    rows.append(fmt_row("kv_serving/fixed_50_50", fixed["total_cost"],
                        f"phase_costs={fixed['costs']}"))
    rows.append(fmt_row("kv_serving/adaptive", adap["total_cost"],
                        f"phase_costs={adap['costs']};"
                        f"final_pool={adap['pool_pages']}"))
    n_lsm = 6_000 if smoke else (60_000 if full else 20_000)
    n_recs = 8_000 if smoke else 60_000
    # smoke shrinks the write memory so flush arbitration still triggers
    wm = (MB // 4) if smoke else 1 * MB
    for policy in ("mem", "lsn", "opt"):
        m = lsm_hot_key(policy, n_lsm, n_records=n_recs,
                        write_mem_bytes=wm)
        rows.append(fmt_row(
            f"kv_serving/lsm_hot_skew/{policy}", m["throughput"],
            f"io_per_op={m['io_pages_per_op']:.3f};stalls={m['stalls']};"
            f"flushes_mem={m['flushes_mem']};flushes_log={m['flushes_log']}"))
    m = lsm_hot_key("opt", n_lsm, merge_budget=4, n_records=n_recs,
                    write_mem_bytes=wm)
    rows.append(fmt_row(
        "kv_serving/lsm_hot_skew/opt_budget4", m["throughput"],
        f"io_per_op={m['io_pages_per_op']:.3f};stalls={m['stalls']};"
        f"carried_debt={m['carried_debt']};ticks={m['ticks']}"))
    n_mixed = 4_000 if smoke else 20_000
    m = service_mixed(n_mixed, n_records=n_recs)
    rows.append(fmt_row(
        "kv_serving/service_mixed", m["throughput"],
        f"submits={m['submits']};ops={m['ops']};stalls={m['stalls']};"
        f"deferred={m['deferred']}"))
    n_hot = 30 if smoke else 200
    for mode, fused in (("staged", False), ("fused", True)):
        for ssts in ((4, 16) if smoke else (4, 16, 64)):
            m = read_hot_path(n_hot, sst_count=ssts, fused=fused)
            rows.append(fmt_row(
                f"kv_serving/read_hot_path/{mode}/ssts{ssts}",
                m["lookup_p50_us"],
                f"scheme={mode};sst_count={m['sst_count']};"
                f"lookup_p50_us={m['lookup_p50_us']:.3f};"
                f"lookup_p99_us={m['lookup_p99_us']:.3f};"
                f"device_pool_hit_rate={m.get('device_pool_hit_rate', 0):.3f};"
                f"jit_compiles={m['jit_compiles']};"
                f"jit_cache_hits={m['jit_cache_hits']};"
                f"read_pages_per_op={m['read_pages_per_op']:.3f}"))
    n_ct = 20 if smoke else 120
    n_ct_recs = 32_768 if smoke else 131_072
    for mode, scope in (("staged", None), ("fused_tier", "tier"),
                        ("fused_store", "store")):
        m = cross_tier_read(n_ct, fused_scope=scope, n_records=n_ct_recs)
        rows.append(fmt_row(
            f"kv_serving/cross_tier_read/{mode}", m["lookup_p50_us"],
            f"scheme={mode};ssts={m['sst_total']};"
            f"launches_per_batch={m['launches_per_batch']:.2f};"
            f"fused_tiers_per_launch={m['fused_tiers_per_launch']:.2f};"
            f"lookup_p50_us={m['lookup_p50_us']:.3f};"
            f"lookup_p99_us={m['lookup_p99_us']:.3f};"
            f"device_pool_hit_rate={m.get('device_pool_hit_rate', 0):.3f}"))
    # The launch-bound regime: same 64 SSTables across 4 levels, pinned
    # to the device backend where a launch is a real kernel dispatch (on
    # the numpy reference a launch is a plain function call, so tier and
    # store scope tie there). Smaller tables keep dispatch -- not
    # per-element interpret cost -- the dominant term.
    n_ct_pl = 8 if smoke else 40
    for mode, scope in (("fused_tier", "tier"), ("fused_store", "store")):
        m = cross_tier_read(n_ct_pl, batch=128, fused_scope=scope,
                            n_records=32_768, per_sst=512,
                            backend="pallas")
        rows.append(fmt_row(
            f"kv_serving/cross_tier_read/{mode}_pallas",
            m["lookup_p50_us"],
            f"scheme={mode}_pallas;ssts={m['sst_total']};"
            f"launches_per_batch={m['launches_per_batch']:.2f};"
            f"fused_tiers_per_launch={m['fused_tiers_per_launch']:.2f};"
            f"lookup_p50_us={m['lookup_p50_us']:.3f};"
            f"lookup_p99_us={m['lookup_p99_us']:.3f}"))
    a = arbiter_flip(2_000 if smoke else 20_000,
                     n_records=8_192 if smoke else 32_768)
    rows.append(fmt_row(
        "kv_serving/cross_tier_read/arbiter", a["shift_bytes"],
        f"arbiter_shift_bytes={a['shift_bytes']};"
        f"dev_lease_read={a['dev_read']};dev_lease_serve={a['dev_serve']};"
        f"kv_lease_serve={a['kv_serve']};leases_sum={a['leases_sum']};"
        f"decisions={a['decisions']}"))
    n_paced = 6_000 if smoke else (48_000 if full else 32_000)
    for label, paced in (("stop_world", False), ("paced", True)):
        m = paced_maintenance(
            n_paced, paced=paced,
            n_records=30_000 if smoke else 120_000)
        rows.append(fmt_row(
            f"kv_serving/paced_maintenance/{label}", m["throughput"],
            f"p50_us={m['p50_us']:.1f};p99_us={m['p99_us']:.1f};"
            f"p999_us={m['p999_us']:.1f};"
            f"max_stall_us={m['max_stall_us']:.1f};"
            f"stalls={m['stalls']};slices={m['slices']};"
            f"deferrals={m['deferrals']};segments={m['segments']};"
            f"ticks={m['ticks']};carried_debt={m['carried_debt']}"))
    n_over = 6_000 if smoke else (48_000 if full else 32_000)
    fps = {}
    for workers in ((0, 2) if smoke else (0, 1, 2, 4)):
        m = overlapped_maintenance(
            n_over, workers=workers,
            n_records=30_000 if smoke else 120_000)
        fps[workers] = m["fingerprint"]
        rows.append(fmt_row(
            f"kv_serving/overlapped_maintenance/w{workers}",
            m["throughput"],
            f"workers={workers};fingerprint={m['fingerprint']};"
            f"p50_us={m['p50_us']:.1f};p99_us={m['p99_us']:.1f};"
            f"p999_us={m['p999_us']:.1f};"
            f"max_stall_us={m['max_stall_us']:.1f};"
            f"bg_segments={m['bg_segments']};"
            f"bg_overlap_us={m['bg_overlap_us']:.1f};"
            f"flush_slices={m['flush_slices']};"
            f"prepares={m['prepares']};hits={m['hits']};"
            f"wasted={m['wasted']};slices={m['slices']};"
            f"deferrals={m['deferrals']};stalls={m['stalls']}"))
    assert len(set(fps.values())) == 1, (
        f"worker arms diverged on data content: {fps}")
    n_shard = 6_000 if smoke else (60_000 if full else 24_000)
    for shards in ([4] if not full else [2, 4, 8]):
        m = sharded_hot_shard(n_shard, shards=shards,
                              n_records=n_recs,
                              write_mem_bytes=(MB // 2) if smoke else 1 * MB)
        rows.append(fmt_row(
            f"kv_serving/sharded_hot_shard/s{shards}", m["throughput"],
            f"scheme=partitioned;shards={shards};stalls={m['stalls']};"
            f"hot_mem_share={m['hot_mem_share']:.3f};"
            f"hot_flush_share={m['hot_flush_share']:.3f};"
            f"io_per_op={m['io_pages_per_op']:.3f};"
            f"tuning_steps={m['tuning_steps']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
