"""Beyond-paper: the memory-walls policies on the TPU serving path.

Compares a fixed 50/50 HBM split between the KV page pool and the prefix
cache against the adaptive HBM tuner, under a prefix-reuse-heavy and an
append-heavy phase. Cost = offload pages + recompute pages per op.
"""
from __future__ import annotations

import numpy as np

from repro.runtime.hbm_tuner import HBMTuner, HBMTunerConfig
from repro.runtime.kvcache import KVPoolConfig, PagedKVPool

from .common import fmt_row


def drive(pool, tuner, n_ops, reuse_frac, rng, working_set=1600,
          req_pages=96, n_streams=24):
    """Requests have finite lifetimes (req_pages) and then free their
    pages — so a bigger pool means fewer offloads (diminishing returns),
    mirroring the LSM write-memory/write-cost relationship."""
    lens = {}
    for i in range(n_ops):
        if rng.random() < reuse_frac:
            pool.lookup_prefix(int(rng.integers(0, working_set)))
        else:
            s = f"s{rng.integers(0, n_streams)}"
            pool.append_tokens(s, pool.cfg.page_tokens)
            lens[s] = lens.get(s, 0) + 1
            if lens[s] >= req_pages:
                pool.finish_stream(s)
                lens[s] = 0
        if tuner is not None:
            tuner.maybe_tune()


def cost_per_op(stats0, stats1, ops):
    off = stats1["offload_pages"] - stats0["offload_pages"]
    rec = (stats1["prefix_misses"] - stats0["prefix_misses"])
    return (off + rec) / max(ops, 1)


def one(adaptive: bool, n_ops=40_000):
    pool = PagedKVPool(KVPoolConfig(page_tokens=16, total_pages=2048,
                                    pool_pages=1024, sim_pages=256,
                                    policy="opt"))
    tuner = HBMTuner(pool, HBMTunerConfig(ops_cycle=1024)) if adaptive \
        else None
    rng = np.random.default_rng(0)
    costs = []
    for phase, reuse in enumerate([0.85, 0.25]):     # reuse-heavy -> append-heavy
        s0 = dict(pool.stats)
        drive(pool, tuner, n_ops // 2, reuse, rng)
        costs.append(cost_per_op(s0, pool.stats, n_ops // 2))
    return {"costs": costs, "pool_pages": pool.cfg.pool_pages,
            "total_cost": sum(costs)}


def run(full: bool = False):
    n = 80_000 if full else 24_000
    rows = []
    fixed = one(False, n)
    adap = one(True, n)
    rows.append(fmt_row("kv_serving/fixed_50_50", fixed["total_cost"],
                        f"phase_costs={fixed['costs']}"))
    rows.append(fmt_row("kv_serving/adaptive", adap["total_cost"],
                        f"phase_costs={adap['costs']};"
                        f"final_pool={adap['pool_pages']}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
