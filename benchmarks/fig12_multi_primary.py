"""Fig. 12: multiple primary LSM-trees under a hotspot distribution.

(a) write-memory sweep at 80-20 skew; (b) skew sweep at fixed memory.
Paper claims: B+-static thrashes (worst); dynamic schemes win; min-LSN ~
optimal > max-memory; Partitioned > B+-dynamic under the same policy, and
the gaps grow with skew.
"""
from __future__ import annotations

import numpy as np

from .common import MB, Workload, bulk_load, fmt_row, make_store, measure

N_TREES = 10


def tree_probs(skew):
    """'80-20': 80% of writes to 20% of the trees."""
    hot_frac, hot_trees = skew
    n_hot = max(1, int(round(N_TREES * hot_trees)))
    p = np.full(N_TREES, (1 - hot_frac) / (N_TREES - n_hot))
    p[:n_hot] = hot_frac / n_hot
    return p


SCHEMES = [("btree-static", "lsn", "b+static"),
           ("btree-static-tuned", "lsn", "b+static-tuned"),
           ("btree-dynamic", "mem", "b+dyn-MEM"),
           ("btree-dynamic", "lsn", "b+dyn-LSN"),
           ("btree-dynamic", "opt", "b+dyn-OPT"),
           ("partitioned", "mem", "part-MEM"),
           ("partitioned", "lsn", "part-LSN"),
           ("partitioned", "opt", "part-OPT")]


def one(scheme, policy, skew, write_mem_mb, n_records=40_000,
        n_ops=150_000):
    real = "btree-static" if scheme == "btree-static-tuned" else scheme
    store = make_store(scheme=real, flush_policy=policy,
                       write_memory_bytes=write_mem_mb * MB,
                       max_log_bytes=8 * MB,
                       max_active_datasets=8 if scheme == "btree-static"
                       else N_TREES)
    names = [f"t{i}" for i in range(N_TREES)]
    for n in names:
        store.create_tree(n)
        bulk_load(store, n, n_records)
    w = Workload(store, names, n_records, tree_probs=tree_probs(skew))
    return measure(store, lambda: w.run(n_ops, write_frac=1.0))


def run(full: bool = False):
    rows = []
    n_ops = 200_000 if full else 80_000
    mems = ([1, 2, 4] if full else [2])
    for mem in mems:                      # (a) memory sweep @ 80-20
        for scheme, policy, label in SCHEMES:
            m = one(scheme, policy, (0.8, 0.2), mem, n_ops=n_ops)
            rows.append(fmt_row(f"fig12a/mem{mem}MB/{label}",
                                m["throughput"],
                                f"wamp={m['write_amp']:.2f}"))
    skews = [(0.5, 0.5), (0.8, 0.2), (0.95, 0.1)] if full \
        else [(0.5, 0.5), (0.95, 0.1)]
    for skew in skews:                    # (b) skew sweep @ 2MB
        for scheme, policy, label in SCHEMES:
            m = one(scheme, policy, skew, 2, n_ops=n_ops)
            rows.append(fmt_row(
                f"fig12b/skew{int(skew[0]*100)}-{int(skew[1]*100)}/{label}",
                m["throughput"], f"wamp={m['write_amp']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
