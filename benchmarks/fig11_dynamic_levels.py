"""Fig. 11: dynamically adjusting disk levels as the write memory changes.

The write memory alternates between large (1MB) and small (64KB) phases;
`dynamic` adjusts the level count (§4.1.3, f=1.5), the static baselines fix
it for one of the extremes. Paper claim: dynamic >= both statics in every
phase; static-large is much worse in the small-memory phase.
"""
from __future__ import annotations

import numpy as np

from .common import KB, MB, Workload, bulk_load, fmt_row, make_store, measure


def one(mode, n_records=150_000, phases=4, ops_per_phase=40_000):
    kw = {}
    if mode == "dynamic":
        kw = dict(dynamic_levels=True)
    else:
        # static level count chosen for the given write-memory size
        storage = n_records * 256
        mem = 1 * MB if mode == "static-large" else 64 * KB
        n = max(1, int(np.ceil(np.log10(storage / mem))))
        kw = dict(dynamic_levels=False, static_num_levels=n)
    store = make_store(scheme="partitioned", flush_policy="lsn",
                       write_memory_bytes=1 * MB, max_log_bytes=8 * MB, **kw)
    store.create_tree("t")
    bulk_load(store, "t", n_records)
    w = Workload(store, ["t"], n_records)
    thr = []
    for ph in range(phases):
        store.set_write_memory(1 * MB if ph % 2 == 0 else 64 * KB)
        m = measure(store, lambda: w.run(ops_per_phase, write_frac=1.0))
        thr.append(m["throughput"])
    return thr


def run(full: bool = False):
    rows = []
    phases = 6 if full else 4
    for mode in ["dynamic", "static-large", "static-small"]:
        thr = one(mode, phases=phases)
        hm = len(thr) / sum(1.0 / max(t, 1e-9) for t in thr)
        rows.append(fmt_row(f"fig11/{mode}", hm,
                            "phases=" + "|".join(f"{t:.0f}" for t in thr)))
    return rows


if __name__ == "__main__":
    print("\n".join(run(full=True)))
