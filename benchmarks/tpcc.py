"""Mini TPC-C-like driver over the LSM store (scaled; read-uncommitted
record ops, as in the paper's AsterixDB setup). Five transaction types with
the standard mix; per-table entry sizes preserve TPC-C's relative row sizes.

Each transaction is submitted to the ``StorageService`` front door as ONE
typed mixed-op request plan: the planner groups the per-table reads/writes
into vectorized steps and the scheduler ticks once per transaction instead
of once per table write. Backpressured writes are drained and retried
(``submit_strict``), so stalls surface in ``IOStats.write_stalls`` and a
transaction whose writes cannot land raises instead of vanishing.
"""
from __future__ import annotations

import numpy as np

from repro.core.service import Get, Put, Scan, StorageService

from .common import bulk_load

TABLES = {          # name: (entry_bytes, rows)
    "warehouse": (96, 64),
    "district": (96, 640),
    "customer": (656, 20_000),
    "history": (48, 20_000),
    "orders": (32, 30_000),
    "new_order": (16, 9_000),
    "order_line": (216, 300_000),
    "item": (80, 20_000),
    "stock": (304, 60_000),
}

MIX = [("new_order", 0.45), ("payment", 0.43), ("order_status", 0.04),
       ("delivery", 0.04), ("stock_level", 0.04)]


class TPCC:
    def __init__(self, store, seed=0):
        self.service = (store if isinstance(store, StorageService)
                        else StorageService(store))
        self.store = self.service.store
        self.rng = np.random.default_rng(seed)
        for name, (eb, rows) in TABLES.items():
            self.service.create_tree(name, dataset=name, entry_bytes=eb)
            bulk_load(self.store, name, rows)
        self.rows = {n: r for n, (_, r) in TABLES.items()}
        self._oid = {n: r for n, r in self.rows.items()}

    def _k(self, table, n=1):
        return self.rng.integers(0, self.rows[table], n)

    def _read(self, table, n=1) -> Get:
        return Get(table, self._k(table, n))

    def _write(self, table, n=1, fresh=False) -> Put:
        if fresh:
            ks = np.arange(self._oid[table], self._oid[table] + n)
            self._oid[table] += n
        else:
            ks = self._k(table, n)
        return Put(table, ks, ks)

    # Each method returns the transaction's request plan (one submit).
    def new_order(self):
        return [self._read("warehouse"), self._read("district"),
                self._read("customer"), self._read("item", 10),
                self._read("stock", 10),
                self._write("district"), self._write("orders", 1, fresh=True),
                self._write("new_order", 1, fresh=True),
                self._write("order_line", 10, fresh=True),
                self._write("stock", 10)]

    def payment(self):
        return [self._read("warehouse"), self._read("district"),
                self._read("customer"),
                self._write("warehouse"), self._write("district"),
                self._write("customer"), self._write("history", 1, fresh=True)]

    def order_status(self):
        return [self._read("customer"), self._read("orders"),
                self._read("order_line", 10)]

    def delivery(self):
        return [self._write("new_order", 10), self._write("orders", 10),
                self._write("order_line", 10), self._write("customer", 10)]

    def stock_level(self):
        return [self._read("district"),
                Scan("order_line", int(self._k("order_line")[0]), 100),
                self._read("stock", 20)]

    def run(self, n_txns, mix=None, on_txn=None):
        mix = mix or MIX
        names = [m[0] for m in mix]
        probs = np.array([m[1] for m in mix])
        probs = probs / probs.sum()
        choices = self.rng.choice(len(names), n_txns, p=probs)
        for c in choices:
            # record ops are not individually counted: one logical op per
            # transaction, as in the paper's TPC-C accounting
            self.service.submit_strict(getattr(self, names[c])(),
                                       count_ops=False)
            self.service.note_ops(1)
            if on_txn is not None:
                on_txn()


READ_MOSTLY = [("new_order", 0.025), ("payment", 0.02),
               ("delivery", 0.005), ("order_status", 0.475),
               ("stock_level", 0.475)]
